"""Serving benchmark: continuous batching vs one-session-at-a-time.

The multi-tenant claim behind `repro.serve.engine`: packing many
streaming sessions into one resident fixed-shape `plan.run` window step
amortizes launch + INTEG cost the way TaiBai amortizes its resident
program across spike streams. This suite replays one deterministic
ragged arrival trace — N concurrent sessions with staggered arrival
times, uneven stream lengths, and uneven chunk sizes — through both
engines and times the whole serve (admission -> cohort windows -> drain):

  * `BatchedEngine` (capacity-C cohorts, the continuous-batching path)
  * `NaiveEngine`   (same scheduler/cache/semantics, B=1 windows)

Timing is paired-adjacent (batched/naive alternating), median per-pair
ratio as the speedup — the same noise discipline as `bench_snn_engine`.
The tracked gate row is `serve_throughput/speedup_x` (relative, survives
runner swaps); sessions/sec, p99 window latency, occupancy, and cache
hit rate ride along for the perf trajectory. Both engines' outputs are
parity-checked (allclose: XLA reduction order differs across batch
shapes, so cross-engine equality is approximate — the *bit-exact*
isolation invariants live in tests/test_serve_engine.py).
"""

from __future__ import annotations

import time
from typing import Dict, List, Tuple

import jax
import numpy as np

from repro.core.snn_layers import make_dhsnn_shd
from repro.serve import EngineConfig, make_engine

N_SESSIONS = 96          # >= 64 concurrent (the acceptance scenario)
WINDOW = 32
CAPACITY = 64
N_IN, N_HIDDEN, N_OUT = 700, 64, 20


def _trace(n_sessions: int, seed: int = 0
           ) -> List[Tuple[int, str, np.ndarray]]:
    """One deterministic ragged arrival trace.

    Returns [(round, sid, chunk)]: session i arrives at round i % 8 and
    then submits one chunk per round until its stream (96..152 steps,
    varying by session) is exhausted. Chunk sizes cycle 17/23/31/40 so
    window boundaries never align with submit boundaries.
    """
    rng = np.random.default_rng(seed)
    sizes = (17, 23, 31, 40)
    ev: List[Tuple[int, str, np.ndarray]] = []
    for i in range(n_sessions):
        sid = f"s{i}"
        total = 96 + 8 * (i % 8)
        x = (rng.random((total, N_IN)) < 0.08).astype(np.float32)
        off, r = 0, i % 8
        while off < total:
            n = min(sizes[(i + r) % len(sizes)], total - off)
            ev.append((r, sid, x[off:off + n]))
            off += n
            r += 1
    ev.sort(key=lambda e: e[0])
    return ev


def _drive(kind: str, nodes, params, trace, cache_bytes=None):
    """Replay the trace through one engine; returns (wall_s, engine)."""
    eng = make_engine(nodes, params,
                      EngineConfig(window=WINDOW, capacity=CAPACITY,
                                   queue_limit=None,
                                   cache_bytes=cache_bytes),
                      kind=kind)
    last_round: Dict[str, int] = {}
    for r, sid, _ in trace:
        last_round[sid] = max(last_round.get(sid, -1), r)
    t0 = time.perf_counter()
    cur = 0
    for r, sid, chunk in trace:
        while r > cur:                      # round boundary: run a window
            eng.step()
            cur += 1
        if sid not in eng.scheduler.sessions:
            eng.open(sid)
        eng.submit(sid, chunk)
        if last_round[sid] == r:
            eng.close(sid)
    eng.drain()
    return time.perf_counter() - t0, eng


def measure(repeats: int = 3) -> Dict:
    nodes, params = make_dhsnn_shd(jax.random.PRNGKey(0), n_in=N_IN,
                                   n_hidden=N_HIDDEN, n_out=N_OUT,
                                   dendritic=False)
    trace = _trace(N_SESSIONS)
    total_steps = sum(len(c) for _, _, c in trace)

    # warm both resident steps (compile outside the timed region)
    _, eb = _drive("batched", nodes, params, trace)
    _, en = _drive("naive", nodes, params, trace)

    # cross-engine parity on a few sessions (allclose, see module doc)
    max_err = 0.0
    for sid in ("s0", "s31", "s95"):
        a, b = eb.outputs(sid), en.outputs(sid)
        assert a.shape == b.shape and a.shape[0] > 0
        max_err = max(max_err, float(np.max(np.abs(a - b))))
    assert max_err < 1e-4, f"engines diverged: max_abs_err={max_err}"

    tb, tn, ratios = [], [], []
    for _ in range(repeats):
        t1, eng_b = _drive("batched", nodes, params, trace)
        t2, _ = _drive("naive", nodes, params, trace)
        tb.append(t1)
        tn.append(t2)
        ratios.append(t2 / t1)
    ratios.sort()
    t_batched, t_naive = min(tb), min(tn)
    snap = eng_b.stats()
    return {
        "n_sessions": N_SESSIONS,
        "window": WINDOW,
        "capacity": CAPACITY,
        "total_steps": total_steps,
        "batched_s": t_batched,
        "naive_s": t_naive,
        "speedup_x": ratios[len(ratios) // 2],
        "speedup_minmax_x": (ratios[0], ratios[-1]),
        "batched_sessions_per_s": N_SESSIONS / t_batched,
        "naive_sessions_per_s": N_SESSIONS / t_naive,
        "batched_steps_per_s": total_steps / t_batched,
        "p50_window_s": snap["window_latency_s"]["p50"],
        "p99_window_s": snap["window_latency_s"]["p99"],
        "occupancy_mean": snap["occupancy"]["mean"],
        "cache_hit_rate": snap["cache_hit_rate"],
        "max_abs_err": max_err,
    }


def measure_cache_pressure() -> Dict:
    """The same trace under a budget that keeps only half the fleet hot:
    spill/restore cost shows up as batched_s inflation, hit rate < 1."""
    nodes, params = make_dhsnn_shd(jax.random.PRNGKey(0), n_in=N_IN,
                                   n_hidden=N_HIDDEN, n_out=N_OUT,
                                   dendritic=False)
    from repro.analysis import session_footprint
    fp = session_footprint(nodes, params)
    trace = _trace(N_SESSIONS)
    budget = (N_SESSIONS // 2) * fp
    t, eng = _drive("batched", nodes, params, trace, cache_bytes=budget)
    snap = eng.stats()
    return {
        "cache_bytes": budget,
        "session_footprint": fp,
        "batched_s": t,
        "cache_hit_rate": snap["cache_hit_rate"],
        "cache_evictions": snap["cache_evictions"],
        "cache_restores": snap["cache_restores"],
    }


def run() -> Dict:
    print("=== serving: continuous batching vs naive one-at-a-time ===")
    m = measure()
    print(f"{m['n_sessions']} sessions x ~{m['total_steps'] // m['n_sessions']}"
          f" steps (W={m['window']}, C={m['capacity']})\n"
          f"batched {m['batched_s']:6.2f} s  naive {m['naive_s']:6.2f} s  "
          f"({m['speedup_x']:4.2f}x, "
          f"{m['batched_sessions_per_s']:6.1f} sessions/s, "
          f"p99 window {1e3 * m['p99_window_s']:.1f} ms, "
          f"occ {m['occupancy_mean']:.2f})")
    assert m["speedup_x"] > 1.0, (
        "continuous batching must beat the naive baseline at "
        f"{m['n_sessions']} concurrent sessions (got {m['speedup_x']:.2f}x)")
    p = measure_cache_pressure()
    print(f"cache pressure: budget {p['cache_bytes']} B "
          f"({p['cache_bytes'] // p['session_footprint']} hot sessions) -> "
          f"{p['batched_s']:6.2f} s, hit rate {p['cache_hit_rate']:.3f}, "
          f"{p['cache_evictions']} evictions")
    return {"serve_throughput": m, "cache_pressure": p}


if __name__ == "__main__":
    run()
