"""Fig. 14 reproduction: fan-in/fan-out table storage, method vs baseline.

Columns (cumulative, as the paper's figure):
  base      fully-connected unrolled mode (every connection explicit)
  +conv     decoupled convolution weight addressing (type-3)
  +psend    parallel-send (one IE serves N NCs instead of N IEs)
  +fcinc    incremental addressing of FC layers (type-2, 4 entries)
The rightmost (ours) is all three. Paper claim: 286-947x total reduction.
"""

from __future__ import annotations

from typing import Dict

from repro.configs.snn_models import MODELS, topology_layers
from repro.core import topology as topo

PARALLEL_SEND_N = 8     # NCs per CC reached by one multicast IE


def measure(model: str) -> Dict[str, float]:
    specs, name = MODELS[model]()
    layers = topology_layers(specs)
    base = sum(t.baseline_bits() for t in layers)

    ours = sum(t.storage_bits() + t.meta.get("extra_bits", 0) for t in layers)

    # ablations (reconstruct intermediate columns analytically):
    # without parallel-send the fan-in tables replicate per reached NC
    no_psend = sum(
        (t.fan_in_bits() * (PARALLEL_SEND_N if t.kind in ("fc", "conv") else 1))
        + t.fan_out_bits() + t.meta.get("extra_bits", 0) for t in layers)
    # without conv decoupling, conv IEs replicate per (c_in x c_out) pair
    no_conv = 0
    for t in layers:
        bits = t.fan_in_bits()
        if t.kind == "conv":
            bits *= t.meta["c_in"] * t.meta["c_out"]
        no_conv += bits + t.fan_out_bits() + t.meta.get("extra_bits", 0)
    # without fc incremental addressing, fc IEs list every destination
    no_fcinc = 0
    for t in layers:
        bits = t.fan_in_bits()
        if t.kind == "fc":
            bits = t.n_post * (topo.BITS["neuron_id"] + topo.BITS["local_axon"])
        no_fcinc += bits + t.fan_out_bits() + t.meta.get("extra_bits", 0)

    return {"model": name, "baseline_bits": base, "ours_bits": ours,
            "no_parallel_send_bits": no_psend, "no_conv_decouple_bits": no_conv,
            "no_fc_incremental_bits": no_fcinc,
            "reduction_x": base / ours}


def run() -> Dict:
    print("=== Fig. 14: topology representation storage ===")
    out = {}
    for model in ("plif_net", "5blocks_net", "resnet19", "vgg16", "resnet18"):
        m = measure(model)
        out[model] = m
        print(f"{m['model']:12s} baseline {m['baseline_bits']/8e6:10.1f} MB   "
              f"ours {m['ours_bits']/8e6:8.3f} MB   "
              f"reduction {m['reduction_x']:7.1f}x")
    red = [m["reduction_x"] for m in out.values()]
    print(f"reduction range: {min(red):.0f}x - {max(red):.0f}x "
          f"(paper: 286x - 947x)")

    # ResNet18 skip-connection core cost vs duplicating cores (paper: 70.3%)
    specs, _ = MODELS["resnet18"]()
    layers = topology_layers(specs)
    skips = [t for t in layers if t.kind == "skip"]
    delayed_bits = sum(t.n_pre * topo.BITS["delay"] for t in skips)
    relay_bits = sum(topo.relay_baseline_bits(t, 2) for t in skips)
    out["resnet18_skip"] = {"delayed_fire_bits": delayed_bits,
                            "relay_bits": relay_bits,
                            "ratio": delayed_bits / relay_bits}
    print(f"ResNet18 skip scheme: delayed-fire {delayed_bits/8e3:.1f} KB vs "
          f"relay {relay_bits/8e3:.1f} KB "
          f"({100*delayed_bits/relay_bits:.1f}% of relay cost)")
    return out


if __name__ == "__main__":
    run()
