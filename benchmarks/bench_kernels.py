"""TPU-adaptation benchmark: event-gated block sparsity effectiveness,
plus the kernel-registry autotune sweep.

The chip exploits word-granular event sparsity; the TPU adaptation skips
(bm x bk) blocks. This benchmark sweeps spike rates (incl. the paper's
measured 1.2 / 2.5 / 8 / 13 / 33 %) and both spike layouts, and reports the
fraction of MXU block-work that survives — the kernel's effective FLOP
fraction — plus the linrec kernel's arithmetic-vs-serial trade.

The autotune section times every registered kernel's candidate block
configs on serving-scale shapes and persists the per-(backend, shape
bucket) winners to the JSON tuning cache (REPRO_TUNING_CACHE, defaulting
here to experiments/kernel_tuning.json so CI archives it)."""

from __future__ import annotations

import os
import zlib
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import registry, tuning
from repro.kernels.spikemm.ops import occupancy_fraction

RATES = (0.012, 0.025, 0.08, 0.13, 0.33)

# spike densities for the dense-vs-sparse channel sweep (the nightly
# speedup-vs-sparsity curve the perf gate tracks)
SPARSITY_DENSITIES = (0.01, 0.05, 0.2, 0.5)
SPARSITY_SHAPE = (2048, 2048, 512)

# serving-scale shapes per kernel family (CPU-interpret friendly; on TPU the
# same sweep runs the real Mosaic kernels on the same buckets)
TUNE_SHAPES = {
    "linrec": lambda key: (
        jax.random.uniform(key, (512, 8, 512), jnp.float32, 0.5, 0.99),
        jax.random.normal(jax.random.fold_in(key, 1), (512, 8, 512)),
        jnp.zeros((8, 512))),
    "lif": lambda key: (
        0.6 * jax.random.normal(key, (256, 8, 512)),
        jax.random.uniform(jax.random.fold_in(key, 1), (512,), jnp.float32,
                           0.7, 0.98),
        jnp.zeros((8, 512))),
    "lifrec": lambda key: (
        0.7 * jax.random.normal(key, (512, 8, 256)),
        (0.3 / 16.0) * jax.random.normal(jax.random.fold_in(key, 1),
                                         (256, 256)),
        jax.random.uniform(jax.random.fold_in(key, 2), (256,), jnp.float32,
                           0.7, 0.98),
        jnp.zeros((8, 256)), jnp.zeros((8, 256))),
    "spikemm": lambda key: (
        (jax.random.uniform(key, (1024, 2048)) < 0.08).astype(jnp.float32),
        jax.random.normal(jax.random.fold_in(key, 1), (2048, 512))),
    "attention": lambda key: (
        jax.random.normal(key, (4, 1024, 64)),
        jax.random.normal(jax.random.fold_in(key, 1), (4, 1024, 64)),
        jax.random.normal(jax.random.fold_in(key, 2), (4, 1024, 64))),
    "stdp": lambda key: tuple(
        f(k) for f, k in zip(
            (lambda k: jax.random.uniform(k, (64, 512)),
             lambda k: (jax.random.uniform(k, (64, 512)) < 0.2
                        ).astype(jnp.float32),
             lambda k: (jax.random.uniform(k, (64, 512)) < 0.2
                        ).astype(jnp.float32),
             lambda k: jax.random.uniform(k, (64, 512)),
             lambda k: 0.5 * jax.random.normal(k, (512, 512))),
            jax.random.split(key, 5))),
}


def run_autotune() -> Dict:
    print("=== kernel-registry autotune: block-config sweep ===")
    cache = tuning.TuningCache(os.environ.get(
        "REPRO_TUNING_CACHE", os.path.join("experiments",
                                           "kernel_tuning.json")))
    registry.ensure_registered()
    out = {"cache_path": cache.path, "kernels": {}}
    key = jax.random.PRNGKey(42)
    for name in registry.names():
        spec = registry.get(name)
        # stable per-kernel fold (hash() is salted per process); fall back to
        # the spec's canonical inputs for families without a bench shape
        kkey = jax.random.fold_in(key, zlib.crc32(name.encode()) % 997)
        make = TUNE_SHAPES.get(name, spec.make_inputs)
        args = make(kkey)
        blocks, report = tuning.autotune(name, args, cache=cache, repeats=2)
        timed = [t for t in report["timings"] if "best_s" in t]
        # baseline = the spec-defaults config, matched explicitly (it may
        # have failed on this backend, in which case speedup is vs winner)
        defaults = spec.resolve_blocks(spec.dims_of(*args), use_cache=False)
        win = report["winner"]["best_s"]
        baseline = next((t["best_s"] for t in timed
                         if t["blocks"] == defaults), win)
        print(f"{name:<10} bucket {report['bucket']:<24} "
              f"winner {blocks} {win*1e3:8.2f} ms "
              f"({baseline/max(win, 1e-12):.2f}x vs defaults, "
              f"{len(timed)} candidates)")
        out["kernels"][name] = {
            "bucket": report["bucket"], "winner": report["winner"],
            "speedup_vs_defaults": baseline / max(win, 1e-12),
            "n_candidates": len(timed),
            "timings": report["timings"],
        }
    print(f"tuning cache -> {cache.path} ({len(cache)} entries)")
    return out


def run_sparsity_sweep(repeats: int = 7) -> Dict:
    """Dense vs block-sparse spikemm channel on population-packed rasters.

    Paired adjacent timing (same machine state for both channels per
    repeat, median ratio) at the densities the perf gate tracks; also
    retunes and persists the dispatch threshold for this shape so the
    nightly artifact carries the crossover the `auto` policy will use.
    """
    import time

    from repro.kernels.spikemm.sparse import (_packed_raster,
                                              tune_sparse_threshold)

    print("=== block-sparse spikemm: dense vs sparse channel ===")
    M, K, N = SPARSITY_SHAPE
    spec = registry.get("spikemm")
    key = jax.random.PRNGKey(7)
    w = jax.random.normal(jax.random.fold_in(key, 1), (K, N), jnp.float32)
    blocks = spec.resolve_blocks({"M": M, "K": K, "N": N}, use_cache=False)
    use_pallas = registry.use_pallas()
    interpret = registry.interpret_mode()

    def dense(s):
        if use_pallas:
            return spec.pallas(s, w, blocks=blocks, interpret=interpret)
        return spec.ref(s, w)

    def sparse(s):
        ch = spec.channels["sparse"]
        if use_pallas:
            return ch.pallas(s, w, blocks=blocks, interpret=interpret)
        return ch.ref(s, w, blocks=blocks)

    out = {"dims": {"M": M, "K": K, "N": N}, "blocks": dict(blocks),
           "rows": {}}
    for d in SPARSITY_DENSITIES:
        s = _packed_raster(jax.random.fold_in(key, 2), M, K, d)
        occ = float(occupancy_fraction(s, blocks["bm"], blocks["bk"]))
        dense(s).block_until_ready()                 # compile + warm
        sparse(s).block_until_ready()
        td, ts = [], []
        for _ in range(repeats):
            t0 = time.perf_counter()
            dense(s).block_until_ready()
            t1 = time.perf_counter()
            sparse(s).block_until_ready()
            td.append(t1 - t0)
            ts.append(time.perf_counter() - t1)
        ratios = sorted(a / b for a, b in zip(td, ts))
        row = {"density": d, "occupancy": occ,
               "dense_ms": 1e3 * min(td), "sparse_ms": 1e3 * min(ts),
               "speedup_x": ratios[len(ratios) // 2],
               "speedup_minmax_x": (ratios[0], ratios[-1])}
        out["rows"][str(d)] = row
        print(f"density {d:5.2f}  occ {occ:.3f}  "
              f"dense {row['dense_ms']:7.2f} ms  "
              f"sparse {row['sparse_ms']:7.2f} ms  "
              f"({row['speedup_x']:5.2f}x)")
    th, report = tune_sparse_threshold(M, K, N, repeats=max(2, repeats // 2))
    out["tuned_threshold"] = th
    out["threshold_ladder"] = report["ladder"]
    print(f"dispatch threshold (occupancy crossover): {th:.3f} "
          f"-> tuning cache")
    return out


def run() -> Dict:
    print("=== event-gated block sparsity: surviving FLOP fraction ===")
    key = jax.random.PRNGKey(0)
    M, K = 4096, 4096
    out = {"random": {}, "structured": {}}
    for rate in RATES:
        s_rand = (jax.random.uniform(key, (M, K)) < rate).astype(jnp.float32)
        # structured: the mapping pass PACKS active populations contiguously
        # (channel-order partition, zigzag placement), so activity occupies a
        # dense corner and whole blocks go silent
        m_act = max(1, int(M * min(1.0, rate * 4)))
        k_act = max(1, int(K * min(1.0, rate * 4)))
        body = (jax.random.uniform(jax.random.fold_in(key, 2),
                                   (m_act, k_act)) < 1 / 16
                ).astype(jnp.float32)
        s_struct = jnp.zeros((M, K)).at[:m_act, :k_act].set(body)
        for name, s in (("random", s_rand), ("structured", s_struct)):
            frac = float(occupancy_fraction(s, 128, 512))
            true_rate = float(jnp.mean(s != 0))
            out[name][rate] = {"block_fraction": frac, "true_rate": true_rate}
        print(f"rate {rate:5.3f}  random-layout blocks {out['random'][rate]['block_fraction']:.3f}  "
              f"structured-layout blocks {out['structured'][rate]['block_fraction']:.3f}")
    print("(random word-sparsity defeats block skipping — the mapping pass's"
          " population packing is what converts event sparsity into TPU wins)")

    # linrec: chunk-parallel arithmetic expansion vs serial
    ct = 256
    expansion = 3 * np.log2(ct) / 2
    print(f"linrec chunk={ct}: {expansion:.1f}x VPU flops vs serial form; "
          f"HBM streams identical (bandwidth-bound => free)")
    out["linrec_expansion"] = expansion

    out["spikemm_sparsity"] = run_sparsity_sweep()
    out["autotune"] = run_autotune()
    return out


if __name__ == "__main__":
    run()
