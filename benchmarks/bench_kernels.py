"""TPU-adaptation benchmark: event-gated block sparsity effectiveness.

The chip exploits word-granular event sparsity; the TPU adaptation skips
(bm x bk) blocks. This benchmark sweeps spike rates (incl. the paper's
measured 1.2 / 2.5 / 8 / 13 / 33 %) and both spike layouts, and reports the
fraction of MXU block-work that survives — the kernel's effective FLOP
fraction — plus the linrec kernel's arithmetic-vs-serial trade."""

from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.spikemm.ops import occupancy_fraction

RATES = (0.012, 0.025, 0.08, 0.13, 0.33)


def run() -> Dict:
    print("=== event-gated block sparsity: surviving FLOP fraction ===")
    key = jax.random.PRNGKey(0)
    M, K = 4096, 4096
    out = {"random": {}, "structured": {}}
    for rate in RATES:
        s_rand = (jax.random.uniform(key, (M, K)) < rate).astype(jnp.float32)
        # structured: the mapping pass PACKS active populations contiguously
        # (channel-order partition, zigzag placement), so activity occupies a
        # dense corner and whole blocks go silent
        m_act = max(1, int(M * min(1.0, rate * 4)))
        k_act = max(1, int(K * min(1.0, rate * 4)))
        body = (jax.random.uniform(jax.random.fold_in(key, 2),
                                   (m_act, k_act)) < 1 / 16
                ).astype(jnp.float32)
        s_struct = jnp.zeros((M, K)).at[:m_act, :k_act].set(body)
        for name, s in (("random", s_rand), ("structured", s_struct)):
            frac = float(occupancy_fraction(s, 128, 512))
            true_rate = float(jnp.mean(s != 0))
            out[name][rate] = {"block_fraction": frac, "true_rate": true_rate}
        print(f"rate {rate:5.3f}  random-layout blocks {out['random'][rate]['block_fraction']:.3f}  "
              f"structured-layout blocks {out['structured'][rate]['block_fraction']:.3f}")
    print("(random word-sparsity defeats block skipping — the mapping pass's"
          " population packing is what converts event sparsity into TPU wins)")

    # linrec: chunk-parallel arithmetic expansion vs serial
    ct = 256
    expansion = 3 * np.log2(ct) / 2
    print(f"linrec chunk={ct}: {expansion:.1f}x VPU flops vs serial form; "
          f"HBM streams identical (bandwidth-bound => free)")
    out["linrec_expansion"] = expansion
    return out


if __name__ == "__main__":
    run()
