"""benchmarks — one module per paper table/figure + the roofline reporter.

  bench_topology_storage   Fig. 14   2-level-table storage vs unrolled baseline
  bench_snn_models         Fig. 13d  Table II SNNs: TaiBai vs GPU power/efficiency
  bench_mapping_tradeoff   Fig. 13e  cores <-> throughput/efficiency trade-off
  bench_applications       Fig. 15   ECG / SHD / BCI accuracy + energy, incl.
                                     the homogeneous ablations
  bench_energy             Tab. III/IV  pJ/SOP + chip characteristics
  bench_kernels            (TPU adaptation) event-gated block-skip FLOP fraction
  bench_roofline           §Roofline reporter from experiments/ JSON records

Run everything: PYTHONPATH=src python -m benchmarks.run
"""
