"""On-chip learning benchmark: per-step vs plan-lowered SynapsePrograms.

Every built-in learning rule is a declarative `SynapseProgram`
(core/plasticity.py); the plan compiler lowers matching rules to the
fused `stdp_seq` family — trace DIFFs hoisted through all-T `linrec`, all
T outer-product updates applied with the weight tile VMEM-resident — while
the per-step path scans `synapse_step` (T sequential einsum+clip rounds,
the weight round-tripping memory every step; this is also what the
hand-rolled stepper loop used to cost). Rows time `plan.run(learn=True)`
end to end on a plastic 2-layer Program under both lowerings, so the
ratio is the real training-loop win, forward included; `rule_only` rows
isolate the learning pass on precomputed spike trains.

Parity (`max_abs_err` on the learned weight) is asserted per row: a
speedup that changes the trajectory is a bug, not a result.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict

import jax
import jax.numpy as jnp

from repro.core import plan, plasticity
from repro.core.snn_layers import make_plastic_ff

RULES = ("pair_stdp", "triplet_stdp", "reward_stdp")


def _force_step(compiled: plan.Plan) -> plan.Plan:
    return dataclasses.replace(compiled, plastic=tuple(
        dataclasses.replace(p, lower=plan.SYN_STEP, reason="forced")
        for p in compiled.plastic))


def _time_paired(fns, repeats: int = 9):
    """Interleaved adjacent-pair timing (see bench_snn_engine)."""
    for fn in fns:
        jax.block_until_ready(fn())                  # compile + warm
    samples = [[] for _ in fns]
    for _ in range(repeats):
        for i, fn in enumerate(fns):
            t0 = time.perf_counter()
            jax.block_until_ready(fn())
            samples[i].append(time.perf_counter() - t0)
    ratios = sorted(a / b for a, b in zip(*samples))
    return [min(s) for s in samples], ratios[len(ratios) // 2]


def measure_program(rule_name: str, T=300, B=8, n_in=256, n_hidden=128
                    ) -> Dict:
    """Full plastic Program: forward + learning, step vs fused lowering."""
    rule = plasticity.make_synapse(rule_name)
    nodes, params = make_plastic_ff(jax.random.PRNGKey(0), n_in=n_in,
                                    n_hidden=n_hidden, rule=rule)
    x = (jax.random.uniform(jax.random.PRNGKey(1), (T, B, n_in)) < 0.15
         ).astype(jnp.float32)
    mod = (jax.random.uniform(jax.random.PRNGKey(2), (T,))
           if rule_name == "reward_stdp" else None)
    compiled = plan.compile_program(nodes)
    assert compiled.plastic[0].lower == plan.SYN_SEQ, compiled.describe()
    stepped = _force_step(compiled)

    def w_of(p):
        st, _, _ = plan.run(nodes, params, x, plan=p, mod=mod)
        return st["hidden"]["syn:input"]["w"]

    fused = jax.jit(lambda: w_of(compiled))
    step = jax.jit(lambda: w_of(stepped))
    err = float(jnp.max(jnp.abs(fused() - step())))
    (t_step, t_fused), speedup = _time_paired((step, fused))
    assert err < 1e-4, (rule_name, err)
    return {
        "plan": compiled.describe(),
        "step_ms": 1e3 * t_step,
        "fused_ms": 1e3 * t_fused,
        "speedup_x": speedup,
        "steps_per_s_fused": T / t_fused,
        "steps_per_s_step": T / t_step,
        "max_abs_err": err,
    }


def measure_rule_only(rule_name: str, T=300, B=8, M=256, N=128) -> Dict:
    """Learning pass alone on precomputed trains: synapse_run scan vs the
    linrec-hoisted stdp_seq lowering."""
    rule = plasticity.make_synapse(rule_name)
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    pre = (jax.random.uniform(ks[0], (T, B, M)) < 0.15).astype(jnp.float32)
    post = (jax.random.uniform(ks[1], (T, B, N)) < 0.15).astype(jnp.float32)
    w = 0.3 * jax.random.normal(ks[2], (M, N), jnp.float32)
    mod = (jnp.ones((T,)) if rule_name == "reward_stdp" else None)
    mod_full = plan._mod_full(mod, T, B, N, jnp.float32) if any(
        "mod" in t.post for t in rule.terms) else None
    syn0 = plasticity.synapse_init(rule, w, B)

    step = jax.jit(lambda: plasticity.synapse_run(rule, w, pre, post,
                                                  mod)["w"])
    fused = jax.jit(lambda: plan._learn_fused(rule, syn0, pre, post,
                                              mod_full)["w"])
    err = float(jnp.max(jnp.abs(fused() - step())))
    (t_step, t_fused), speedup = _time_paired((step, fused))
    assert err < 1e-4, (rule_name, err)
    upd_per_s = T * M * N / t_fused                  # synapse-updates/s
    return {
        "step_ms": 1e3 * t_step,
        "fused_ms": 1e3 * t_fused,
        "speedup_x": speedup,
        "synapse_updates_per_s": upd_per_s,
        "max_abs_err": err,
    }


def run() -> Dict:
    print("=== plasticity: per-step vs plan-lowered SynapsePrograms ===")
    out: Dict[str, Dict] = {}
    for name in RULES:
        m = measure_program(name)
        out[name] = m
        print(f"{name:18s} {m['step_ms']:8.2f} ms -> {m['fused_ms']:7.2f} ms "
              f"({m['speedup_x']:5.2f}x, err {m['max_abs_err']:.1e})")
        r = measure_rule_only(name)
        out[f"{name}_rule_only"] = r
        print(f"{name + '_rule':18s} {r['step_ms']:8.2f} ms -> "
              f"{r['fused_ms']:7.2f} ms ({r['speedup_x']:5.2f}x, "
              f"{r['synapse_updates_per_s']:.2e} syn-upd/s)")
    return out


if __name__ == "__main__":
    run()
