"""Stepper-vs-plan engine benchmark: wall clock and step throughput for the
paper's application models through both SNN execution engines.

The generic stepper (`events.run`) interprets a Program timestep by
timestep; the plan compiler (`core/plan.py`) pattern-matches each node's
NeuronProgram, hoists INTEG out of the time scan (one all-T spikemm per
feed, branch-flattened for dendritic models) and fuses FIRE into
whole-(T,B,N) kernel launches (`lif` / `lifrec` / `alif` / `alifrec` /
`linrec`). Since the neuron-program IR landed, ALL application models fuse
with zero fallback segments — the ALIF (`srnn_ecg_alif`, `shd_alif_ff`)
and DH-LIF (`shd_dhlif`) hidden-layer rows exist precisely to track the
newly fused dynamics' stepper-vs-plan ratio nightly, next to the LIF rows
that fused from the start.

The headline row is `shd_ff`, the DHSNN-SHD-shaped feed-forward stack
(700 -> 64 LIF -> 20 LI readout) at streaming batch: the stepper pays T
launches of a skinny (B, 700) matmul that can't feed wide matmul units —
at edge-inference batch sizes that is latency-bound and hoisted INTEG wins
3-5x even on CPU BLAS. A large-batch training-shaped row is reported too,
where big-batch BLAS narrows the forward gap to ~2x (the TPU kernels, not
measured here, reopen it via block skipping and VMEM-resident state).
"""

from __future__ import annotations

import time
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp

from repro.core import events, plan
from repro.core.neuron import ALIF, LI
from repro.core.snn_layers import ff_integrate, make_dhsnn_shd, make_srnn_ecg
from repro.kernels.spikemm.ops import occupancy_fraction


def _workloads(key) -> List[Tuple[str, list, dict, jax.Array]]:
    k1, k2, k3 = jax.random.split(key, 3)
    out = []
    # DHSNN-SHD-shaped feed-forward. Headline: streaming inference, one
    # ~1s utterance at 1 ms bins (T=1000, B=1) — the chip's edge scenario,
    # where the stepper's 1000 skinny-matmul launches are pure latency.
    # Plus a training-shaped batch row where big-batch BLAS narrows the gap.
    nodes, params = make_dhsnn_shd(k1, n_hidden=64, dendritic=False)
    x1 = (jax.random.uniform(k1, (1000, 1, 700)) < 0.08).astype(jnp.float32)
    x4 = (jax.random.uniform(k1, (250, 4, 700)) < 0.08).astype(jnp.float32)
    x64 = (jax.random.uniform(k1, (250, 64, 700)) < 0.08).astype(jnp.float32)
    out.append(("shd_ff", nodes, params, x1))
    out.append(("shd_ff_b64", nodes, params, x64))
    # full DH-LIF model: branch-integrate prologue (linrec) + fused soma lif
    nodes, params = make_dhsnn_shd(k2, n_hidden=64, dendritic=True)
    out.append(("shd_dhlif", nodes, params, x4))
    # SHD-shaped ALIF feed-forward hidden: the `alif` kernel family
    alif_nodes = [events.LayerNode("hidden", ALIF(beta=0.5), ff_integrate,
                                   ("input",), 64),
                  events.LayerNode("readout", LI(tau=0.97), ff_integrate,
                                   ("hidden",), 20)]
    ka, kb, kc = jax.random.split(k2, 3)
    alif_params = {
        "hidden": {"w_input": (1.0 / jnp.sqrt(700.0)) *
                   jax.random.normal(ka, (700, 64)),
                   "neuron": ALIF().param_init(kb, (64,))},
        "readout": {"w_hidden": (1.0 / 8.0) * jax.random.normal(kc, (64, 20))},
    }
    out.append(("shd_alif_ff", alif_nodes, alif_params, x4))
    # SRNN-ECG homogeneous: recurrent hidden -> lifrec kernel path
    nodes, params = make_srnn_ecg(k3, heterogeneous=False, n_hidden=64)
    xe = (jax.random.uniform(k3, (200, 4, 4)) < 0.3).astype(jnp.float32)
    out.append(("srnn_ecg_rec", nodes, params, xe))
    # SRNN-ECG heterogeneous: recurrent ALIF hidden -> alifrec kernel path
    nodes, params = make_srnn_ecg(k3, heterogeneous=True, n_hidden=64)
    out.append(("srnn_ecg_alif", nodes, params, xe))
    return out


def _time_paired(fns, params, x, repeats: int):
    """Interleave the two fns and collect time-ADJACENT sample pairs.

    On a shared/throttled host, contention drifts on a scale of tens of
    milliseconds; timing fn A's N repeats then fn B's would attribute the
    drift to whichever ran during the burst. Adjacent pairs see the same
    machine state, so the per-pair ratio is stable; the median ratio is the
    robust speedup estimate. Returns (min times, per-pair ratio list).
    """
    for fn in fns:
        fn(params, x).block_until_ready()            # compile + warm
    samples = [[] for _ in fns]
    for _ in range(repeats):
        for i, fn in enumerate(fns):
            t0 = time.perf_counter()
            fn(params, x).block_until_ready()
            samples[i].append(time.perf_counter() - t0)
    ratios = sorted(a / b for a, b in zip(*samples))
    return [min(s) for s in samples], ratios


def measure(name: str, nodes, params, x, repeats: int = 15) -> Dict:
    """Time events.run vs plan.run (jitted) on one workload; verify parity."""
    compiled = plan.compile_program(nodes)
    stepper = jax.jit(lambda p, xx: events.run(nodes, p, xx)[1])
    planned = jax.jit(lambda p, xx: plan.run(nodes, p, xx,
                                             plan=compiled)[1])
    max_err = float(jnp.max(jnp.abs(stepper(params, x) - planned(params, x))))
    (t_step, t_plan), ratios = _time_paired((stepper, planned), params, x,
                                            repeats)
    speedup = ratios[len(ratios) // 2]               # median paired ratio
    T = int(x.shape[0])
    return {
        "plan": compiled.describe(),
        "stepper_ms": 1e3 * t_step,
        "plan_ms": 1e3 * t_plan,
        "speedup_x": speedup,
        "speedup_minmax_x": (ratios[0], ratios[-1]),
        "stepper_steps_per_s": T / t_step,
        "plan_steps_per_s": T / t_plan,
        "max_abs_err": max_err,
        "input_block_occupancy": float(occupancy_fraction(
            x.reshape(T * x.shape[1], -1))),
    }


SPARSITY_DENSITIES = (0.01, 0.05, 0.2, 0.5)


def run_sparsity_rows(repeats: int = 7) -> Dict:
    """Engine-level sparsity sweep: eager plan.run, spikemm channel pinned.

    The hoisted all-T INTEG goes through the spikemm registry dispatch, so
    the sparse channel needs no plan-compiler changes — but it only
    engages when the raster is *concrete* (under jit the occupancy is
    unknowable and dispatch routes dense). These rows therefore run the
    plan engine eagerly, pinning `REPRO_SPIKEMM_SPARSE` to `never` vs
    `always` per timing leg, on population-packed input rasters: the
    end-to-end view of the kernel-level sweep in `bench_kernels`.
    """
    import os

    from repro.kernels.spikemm.sparse import _packed_raster

    print("=== plan engine: dense vs block-sparse INTEG (eager) ===")
    key = jax.random.PRNGKey(5)
    # wide input layer so the hoisted INTEG dominates the plan step — the
    # regime the sparse channel targets (mapped cores see wide fan-in)
    n_in = 4096
    nodes, params = make_dhsnn_shd(key, n_in=n_in, n_hidden=512,
                                   dendritic=False)
    compiled = plan.compile_program(nodes)
    T, B = 256, 8
    out: Dict[str, Dict] = {}
    env = "REPRO_SPIKEMM_SPARSE"
    prev = os.environ.get(env)
    try:
        for d in SPARSITY_DENSITIES:
            x = _packed_raster(jax.random.fold_in(key, 3), T * B, n_in,
                               d).reshape(T, B, n_in)
            occ = float(occupancy_fraction(x.reshape(T * B, n_in)))

            def run_once():
                return plan.run(nodes, params, x, plan=compiled)[1]

            os.environ[env] = "never"
            base = run_once()
            base.block_until_ready()
            os.environ[env] = "always"
            spar = run_once()
            err = float(jnp.max(jnp.abs(spar - base)))
            td, ts = [], []
            for _ in range(repeats):
                os.environ[env] = "never"
                t0 = time.perf_counter()
                run_once().block_until_ready()
                t1 = time.perf_counter()
                os.environ[env] = "always"
                run_once().block_until_ready()
                td.append(t1 - t0)
                ts.append(time.perf_counter() - t1)
            ratios = sorted(a / b for a, b in zip(td, ts))
            row = {"density": d, "input_block_occupancy": occ,
                   "dense_ms": 1e3 * min(td), "sparse_ms": 1e3 * min(ts),
                   "speedup_x": ratios[len(ratios) // 2],
                   "max_abs_err": err}
            out[str(d)] = row
            print(f"density {d:5.2f}  occ {occ:.3f}  "
                  f"dense {row['dense_ms']:8.2f} ms  "
                  f"sparse {row['sparse_ms']:8.2f} ms  "
                  f"({row['speedup_x']:5.2f}x, err {err:.1e})")
    finally:
        if prev is None:
            os.environ.pop(env, None)
        else:
            os.environ[env] = prev
    return out


def run() -> Dict:
    print("=== SNN engine: stepper vs compiled execution plan ===")
    out: Dict[str, Dict] = {}
    for name, nodes, params, x in _workloads(jax.random.PRNGKey(0)):
        m = measure(name, nodes, params, x)
        out[name] = m
        print(f"{name:14s} {m['stepper_ms']:8.2f} ms -> {m['plan_ms']:7.2f} ms "
              f"({m['speedup_x']:5.2f}x, {m['plan_steps_per_s']:9.0f} steps/s, "
              f"err {m['max_abs_err']:.1e})\n"
              f"{'':14s} {m['plan']}")
    assert out["shd_ff"]["max_abs_err"] < 1e-4
    print(f"shd_ff speedup {out['shd_ff']['speedup_x']:.2f}x "
          f"(acceptance floor: 2x on the default backend)")
    out["spikemm_sparsity"] = run_sparsity_rows()
    return out


if __name__ == "__main__":
    run()
