"""Fig. 15 reproduction: the three applications (ECG, SHD speech, BCI) —
accuracy, simulated power, and efficiency vs GPU, including the paper's
'TaiBai-homogeneous' ablations (SRNN w/o heterogeneous neurons, DHSNN w/o
dendrites, BCI w/o on-chip learning).

Datasets are the shape/statistics-faithful synthetic generators (data/
spikes.py) — accuracies are therefore *relative* orderings on this data,
not QTDB/SHD absolute percentages (documented in DESIGN.md §7)."""

from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import events
from repro.core.simulator import LayerStats, simulate
from repro.core.snn_layers import (BCIConfig, bci_finetune_fc, bci_forward,
                                   bci_init, make_dhsnn_shd, make_srnn_ecg)
from repro.data.spikes import gen_bci_trials, gen_ecg_qtdb, gen_shd_spikes


def _clipped_sgd(loss_fn, params, steps, lr):
    grad_fn = jax.jit(jax.value_and_grad(loss_fn))
    for _ in range(steps):
        loss, g = grad_fn(params)
        gn = jnp.sqrt(sum(jnp.sum(jnp.square(x)) for x in jax.tree.leaves(g)))
        sc = jnp.minimum(1.0, 1.0 / (gn + 1e-9))
        params = jax.tree.map(
            lambda p, gg: p - lr * sc * gg if gg is not None else p, params, g)
    return params, float(loss)


def ecg_task(heterogeneous: bool) -> Dict:
    xs, ys = gen_ecg_qtdb(16, T=200)
    x = jnp.asarray(xs.transpose(1, 0, 2))
    y = jnp.asarray(ys.T)
    nodes, params = make_srnn_ecg(jax.random.PRNGKey(0),
                                  heterogeneous=heterogeneous, n_hidden=48)

    def loss(params):
        _, outs, _ = events.run(nodes, params, x)
        logp = jax.nn.log_softmax(outs, -1)
        return -jnp.mean(jnp.take_along_axis(logp, y[..., None], -1))

    params, _ = _clipped_sgd(loss, params, 120, 0.1)
    xt, yt = gen_ecg_qtdb(8, seed=7, T=200)
    _, outs, recs = events.run(nodes, params,
                               jnp.asarray(xt.transpose(1, 0, 2)),
                               record=("hidden",))
    acc = float(jnp.mean(jnp.argmax(outs, -1) == jnp.asarray(yt.T)))
    rate = float(jnp.mean(recs["hidden"]))
    return {"accuracy": acc, "spike_rate": rate,
            "stats": [LayerStats("hidden", 48, 48 + 6, max(rate, 1e-3),
                                 2.0 * 48 * (4 + 48))]}


def shd_task(dendritic: bool) -> Dict:
    xs, ys = gen_shd_spikes(32, T=60)
    x = jnp.asarray(xs.transpose(1, 0, 2))
    y = jnp.asarray(ys)
    nodes, params = make_dhsnn_shd(jax.random.PRNGKey(1), n_hidden=48,
                                   dendritic=dendritic)

    def loss(params):
        _, outs, _ = events.run(nodes, params, x)
        logits = jnp.mean(outs, 0)
        return -jnp.mean(jax.nn.log_softmax(logits)[jnp.arange(len(y)), y])

    params, _ = _clipped_sgd(loss, params, 120, 0.2)
    xt, yt = gen_shd_spikes(32, T=60, seed=11)
    _, outs, recs = events.run(nodes, params,
                               jnp.asarray(xt.transpose(1, 0, 2)),
                               record=("hidden",))
    acc = float(jnp.mean(jnp.argmax(jnp.mean(outs, 0), -1) == jnp.asarray(yt)))
    rate = float(jnp.mean(recs["hidden"]))
    return {"accuracy": acc, "spike_rate": rate,
            "stats": [LayerStats("hidden", 48, 20, max(rate, 1e-3),
                                 2.0 * 48 * (4 * 700))]}


def bci_task(onchip: bool) -> Dict:
    cfg = BCIConfig(n_channels=64, n_steps=30, n_paths=8, d_path=16)
    params = bci_init(jax.random.PRNGKey(2), cfg)
    x0, y0 = gen_bci_trials(128, day=0, n_channels=64, n_bins=30)
    x0j, y0j = jnp.asarray(x0), jnp.asarray(y0)

    def loss(params):
        logits, _ = bci_forward(params, x0j, cfg)
        return -jnp.mean(jax.nn.log_softmax(logits)[jnp.arange(len(y0)), y0j])

    params, _ = _clipped_sgd(loss, params, 250, 0.1)

    accs = []
    rates = []
    for day in (1, 2, 3):
        xt, yt = gen_bci_trials(64, day=day, n_channels=64, n_bins=30, seed=day)
        p = params
        if onchip:
            xf, yf = gen_bci_trials(32, day=day, n_channels=64, n_bins=30,
                                    seed=100 + day)
            p, _ = bci_finetune_fc(params, jnp.asarray(xf), jnp.asarray(yf),
                                   cfg, lr=0.05, steps=25)
        logits, spikes = bci_forward(p, jnp.asarray(xt), cfg)
        accs.append(float(jnp.mean(jnp.argmax(logits, -1) == jnp.asarray(yt))))
        rates.append(float(jnp.mean(spikes)))
    rate = float(np.mean(rates))
    return {"accuracy": float(np.mean(accs)), "spike_rate": rate,
            "stats": [LayerStats("paths", 8 * 16, 64, max(rate, 1e-3),
                                 2.0 * 8 * 16 * 64 * 30)]}


def run() -> Dict:
    print("=== Fig. 15: applications (accuracy / power / efficiency) ===")
    out = {}
    for name, fn, flag_name in (("ecg_srnn", ecg_task, "heterogeneous"),
                                ("shd_dhsnn", shd_task, "dendritic"),
                                ("bci_decoder", bci_task, "on-chip learning")):
        full = fn(True)
        homog = fn(False)
        rep = simulate(full["stats"], timesteps=100)
        out[name] = {
            "accuracy": full["accuracy"],
            "accuracy_homogeneous": homog["accuracy"],
            "spike_rate": full["spike_rate"],
            "power_w": rep.power_w, "gpu_power_w": rep.gpu_power_w,
            "power_ratio_x": rep.power_ratio_x,
            "efficiency_x": rep.efficiency_x,
        }
        marker = "+" if full["accuracy"] >= homog["accuracy"] else "-"
        print(f"{name:12s} acc {full['accuracy']:.3f} "
              f"(homog {homog['accuracy']:.3f} [{marker}], no {flag_name})  "
              f"power {rep.power_w:5.2f} W ({rep.power_ratio_x:5.0f}x less)  "
              f"eff {rep.efficiency_x:6.1f}x")
    mean_p = np.mean([m["power_w"] for m in out.values()])
    print(f"mean TaiBai power {mean_p:.2f} W (paper: ~0.34 W); "
          f"efficiency ratios (paper: 296-855x)")
    return out


if __name__ == "__main__":
    run()
