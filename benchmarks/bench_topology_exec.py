"""Compressed-topology *execution*: throughput and peak memory vs dense.

`bench_topology_storage` reproduces the paper's Fig. 14 storage claims;
this suite measures what the tables buy at run time now that the stack
executes them directly through the `spikemm_gather` channel:

  exec_vs_dense   paired throughput, gather channel on IE tables vs the
                  dense spikemm on `dense_equivalent()` — same banded
                  connectivity, moderate scale where dense is feasible
  scale_1e5/1e6   brain-scale banded nets (10^5 / 10^6 neurons) run
                  compressed-only; the dense path is *modeled* (its
                  weight tensor alone is 40 GB / 4 TB) and reported as a
                  bytes ratio — the row CI gates is deterministic
  stream_memory   subprocess peak-RSS rows: `plan.run_stream` on an 8x
                  longer stream must hold RSS constant while the one-shot
                  full-time path pays linearly (ISSUE acceptance, same
                  property `tests/test_topology_exec.py` asserts)

All gated rows are relative (paired speedups, byte ratios, RSS ratios) so
they survive runner hardware swaps, matching the tracked.json contract.
"""

from __future__ import annotations

import subprocess
import sys
import textwrap
import time
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import topology as topo
from repro.kernels.spikemm.gather import spikemm_gather
from repro.kernels.spikemm.ops import spikemm


def _banded(n: int, band: int, seed: int = 0):
    """Local/banded connectivity: each neuron reaches ±band neighbours —
    the locality regime where block-structured IE lowering is dense per
    occupied block (cortical-sheet-like wiring)."""
    rng = np.random.default_rng(seed)
    rows = np.repeat(np.arange(n), 2 * band + 1)
    cols = rows + np.tile(np.arange(-band, band + 1), n)
    keep = (cols >= 0) & (cols < n)
    w = 0.1 * rng.standard_normal(keep.sum()).astype(np.float32)
    return topo.encode((rows[keep], cols[keep], w), kind="sparse_coo",
                       n_pre=n, n_post=n)


def _tables_bytes(t) -> int:
    return int(t.wblk.nbytes + t.jj.nbytes + t.kk.nbytes + t.act.nbytes)


def _paired(fa, fb, repeats: int = 9):
    """Adjacent-pair timing (same rationale as bench_snn_engine)."""
    fa().block_until_ready()
    fb().block_until_ready()
    ratios, ta, tb = [], [], []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fa().block_until_ready()
        t1 = time.perf_counter()
        fb().block_until_ready()
        t2 = time.perf_counter()
        ta.append(t1 - t0)
        tb.append(t2 - t1)
        ratios.append((t2 - t1) / (t1 - t0))
    ratios.sort()
    return min(ta), min(tb), ratios[len(ratios) // 2]


def measure_exec_vs_dense(n: int = 8192, band: int = 64,
                          m: int = 64) -> Dict:
    enc = _banded(n, band)
    tables = enc.lowering()
    w_dense = jnp.asarray(enc.dense_equivalent())
    x = jnp.asarray((np.random.default_rng(1).random((m, n)) < 0.2),
                    jnp.float32)
    f_gather = jax.jit(lambda: spikemm_gather(x, tables))
    f_dense = jax.jit(lambda: spikemm(x, w_dense))
    err = float(jnp.max(jnp.abs(f_gather() - f_dense())))
    t_g, t_d, speedup = _paired(f_gather, f_dense)
    return {
        "n": n, "band": band, "edges": int(enc.meta["n_connections"]),
        "gather_ms": 1e3 * t_g, "dense_ms": 1e3 * t_d,
        "speedup_x": speedup,                 # dense time / gather time
        "max_abs_err": err,
        "dense_bytes": int(w_dense.size * 4),
        "compressed_bytes": _tables_bytes(tables),
    }


def measure_scale(n: int, band: int, bk: int, steps: int = 8) -> Dict:
    """Compressed-only execution at a scale where dense is infeasible."""
    t0 = time.perf_counter()
    enc = _banded(n, band)
    tables = enc.lowering(bk=bk, bn=bk)
    build_s = time.perf_counter() - t0
    x = jnp.asarray((np.random.default_rng(2).random((8, n)) < 0.1),
                    jnp.float32)
    f = jax.jit(lambda s: spikemm_gather(s, tables))
    f(x).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(steps):
        f(x).block_until_ready()
    dt = (time.perf_counter() - t0) / steps
    comp = _tables_bytes(tables)
    dense_model = n * n * 4
    return {
        "n": n, "band": band, "bk": bk,
        "edges": int(enc.meta["n_connections"]),
        "build_s": build_s, "step_ms": 1e3 * dt,
        "steps_per_s": 1.0 / dt,
        "compressed_bytes": comp,
        "modeled_dense_bytes": dense_model,
        "mem_ratio_dense_over_compressed": dense_model / comp,
        "storage_table_bytes": enc.storage_bits() // 8,
    }


_MEM_SCRIPT = textwrap.dedent("""
    import sys
    import numpy as np
    import jax, jax.numpy as jnp
    from repro.core import events, plan
    from repro.core import topology as topo
    from repro.core.events import Connection
    from repro.core.neuron import LI, LIF
    from repro.core.snn_layers import ff_integrate

    mode, T = sys.argv[1], int(sys.argv[2])
    n, band, chunk = 8192, 64, 64
    rows = np.repeat(np.arange(n), 2 * band + 1)
    cols = rows + np.tile(np.arange(-band, band + 1), n)
    keep = (cols >= 0) & (cols < n)
    w = 0.05 * np.ones(keep.sum(), np.float32)
    enc = topo.encode((rows[keep], cols[keep], w), kind="sparse_coo",
                      n_pre=n, n_post=n)
    nodes = [
        events.LayerNode("h", LIF(tau=0.8, v_th=0.6), ff_integrate,
                         (Connection("input", topology=enc),), n),
        events.LayerNode("ro", LI(tau=0.9), ff_integrate, ("h",), 8),
    ]
    params = {"h": {}, "ro": {"w_h": 0.1 * np.ones((n, 8), np.float32)}}
    rng = np.random.default_rng(0)

    def chunks():
        for _ in range(T // chunk):
            yield jnp.asarray((rng.random((chunk, 1, n)) < 0.2),
                              jnp.float32)

    if mode == "stream":
        for st, out in plan.run_stream(nodes, params, chunks()):
            out.block_until_ready()
    else:
        x = jnp.concatenate(list(chunks()), axis=0)
        _, out, _ = plan.run(nodes, params, x)
        out.block_until_ready()
    # peak RSS via VmHWM: unlike ru_maxrss it resets on exec, so a large
    # launching process cannot taint the measurement through fork
    hwm = [l for l in open("/proc/self/status") if l.startswith("VmHWM")]
    print(hwm[0].split()[1])
""")


def _peak_rss_kb(mode: str, T: int) -> int:
    r = subprocess.run([sys.executable, "-c", _MEM_SCRIPT, mode, str(T)],
                       capture_output=True, text=True, timeout=900)
    if r.returncode != 0:
        raise RuntimeError(r.stderr[-2000:])
    return int(r.stdout.strip().splitlines()[-1])


def measure_stream_memory(t_short: int = 256, t_long: int = 2048) -> Dict:
    short = _peak_rss_kb("stream", t_short)
    long_ = _peak_rss_kb("stream", t_long)
    oneshot = _peak_rss_kb("oneshot", t_long)
    return {
        "t_short": t_short, "t_long": t_long,
        "stream_short_rss_kb": short,
        "stream_long_rss_kb": long_,
        "oneshot_long_rss_kb": oneshot,
        # constancy: ~1.0 when streaming peak memory is flat in T
        "long_over_short_rss": long_ / short,
        # linear growth of the full-time path over the streaming footprint
        "oneshot_over_stream_rss": oneshot / long_,
    }


def run() -> Dict:
    print("=== compressed-topology execution vs dense ===")
    out: Dict = {}

    r = measure_exec_vs_dense()
    out["exec_vs_dense"] = r
    print(f"n={r['n']} band={r['band']}: gather {r['gather_ms']:.2f} ms vs "
          f"dense {r['dense_ms']:.2f} ms  -> {r['speedup_x']:.2f}x "
          f"(err {r['max_abs_err']:.1e}, "
          f"{r['dense_bytes'] / r['compressed_bytes']:.0f}x less memory)")

    for key, (n, band, bk) in {"scale_1e5": (100_000, 32, 128),
                               "scale_1e6": (1_000_000, 2, 32)}.items():
        r = measure_scale(n, band, bk)
        out[key] = r
        print(f"n={r['n']:>9,} band={r['band']}: {r['step_ms']:8.2f} ms/step "
              f"compressed ({r['compressed_bytes'] / 2**20:.0f} MB tables); "
              f"dense modeled {r['modeled_dense_bytes'] / 2**30:.0f} GB "
              f"-> {r['mem_ratio_dense_over_compressed']:.0f}x")

    r = measure_stream_memory()
    out["stream_memory"] = r
    print(f"stream RSS T={r['t_short']}: {r['stream_short_rss_kb']//1024} MB"
          f"  T={r['t_long']}: {r['stream_long_rss_kb']//1024} MB "
          f"(x{r['long_over_short_rss']:.2f}); one-shot T={r['t_long']}: "
          f"{r['oneshot_long_rss_kb']//1024} MB "
          f"(x{r['oneshot_over_stream_rss']:.2f} over streaming)")
    return out


if __name__ == "__main__":
    run()
