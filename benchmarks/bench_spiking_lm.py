"""Paper-technique composability on LMs: spiking (binarized) FFN activations.

DESIGN.md §6 claims the TaiBai technique composes onto the assigned LM
architectures via `spiking_ffn` without breaking training. This benchmark
trains a reduced qwen2-family model with and without spiking FFN on the
markov stream and reports:
  * final loss (both must learn),
  * the FFN event rate (fraction of nonzero hidden activations),
  * the block-occupancy fraction the spikemm kernel would execute at that
    rate (the deployment-path FLOP fraction for the down-projection).
"""

from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.data.tokens import TokenStream
from repro.kernels.spikemm.ops import occupancy_fraction
from repro.models import lm
from repro.core.surrogate import spike
from repro.optim.adamw import AdamWConfig

STEPS = 40


def _train(spiking: bool) -> Dict:
    cfg = get_smoke_config("qwen2-1.5b").replace(
        dtype="float32", vocab_size=64, spiking_ffn=spiking)
    stream = TokenStream(cfg.vocab_size, 32, 8, seed=0)
    state = lm.init_train_state(jax.random.PRNGKey(0), cfg)
    step = jax.jit(lm.make_train_step(cfg, AdamWConfig(lr=3e-3)))
    loss = None
    for i in range(STEPS):
        state, m = step(state, {"tokens": jnp.asarray(
            stream.batch_at(i)["tokens"])})
        loss = float(m["loss"])

    # measure the FFN event rate on a held-out batch
    batch = jnp.asarray(stream.batch_at(999)["tokens"])[:, :-1]
    params = state["params"]
    from repro.models.blocks import embed_apply, rms_norm
    h = embed_apply(params["embed"], batch, jnp.float32)
    layer0 = jax.tree.map(lambda x: x[0], params["layers"])
    # the real block normalizes before the MLP — the probe must too
    x = rms_norm(h, layer0["norm2"], cfg.norm_eps)
    dt = h.dtype
    hmid = jax.nn.silu(x @ layer0["mlp"]["w_gate"].astype(dt)) * (
        x @ layer0["mlp"]["w_up"].astype(dt))
    if spiking:
        # the same binarization mlp_apply uses (keep in sync with blocks.py)
        hmid = spike(hmid - 0.05, "sigmoid", 4.0)
    rate = float(jnp.mean(hmid != 0))
    occ = float(occupancy_fraction(hmid.reshape(-1, hmid.shape[-1])))
    return {"loss": loss, "event_rate": rate, "block_occupancy": occ}


def run() -> Dict:
    print("=== spiking-FFN composability on qwen2-family LM ===")
    out = {}
    for spiking in (False, True):
        r = _train(spiking)
        out["spiking" if spiking else "dense"] = r
        print(f"{'spiking' if spiking else 'dense':8s} loss {r['loss']:.3f}  "
              f"FFN event rate {r['event_rate']:.1%}  "
              f"block occupancy {r['block_occupancy']:.2f}")
    lnv = float(jnp.log(64.0))
    assert out["spiking"]["loss"] < lnv, "spiking LM failed to learn"
    print(f"(both < ln(V)={lnv:.2f}: the technique composes; the spiking "
          f"variant's down-projection runs event-gated on kernels/spikemm)")
    return out


if __name__ == "__main__":
    run()
