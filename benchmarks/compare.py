"""Perf-regression gate: diff fresh BENCH_*.json runs against checked-in
baselines and fail CI when a tracked row regresses beyond tolerance.

    PYTHONPATH=src python -m benchmarks.compare experiments \
        [--baselines benchmarks/baselines] [--tolerance 0.2] [--gate]

Design points (why this is robust enough to gate on):

  * **Relative metrics only.** Baselines are recorded on one machine and
    replayed on another, so absolute milliseconds are not portable. The
    tracked rows are speedup ratios (plan-vs-stepper, sparse-vs-dense)
    measured with *paired adjacent* timing inside each bench — those
    cancel host speed and survive a runner swap.
  * **Min-of-k noise guard.** The fresh side may be `--repeats K` output
    (`r0/..r{K-1}/` subdirs); each tracked row takes its *best* value
    across repeats before gating, so one noisy repeat cannot fake a
    regression. The median across repeats is reported alongside.
  * **Manifest-driven.** `benchmarks/baselines/tracked.json` lists the
    gated rows as `{suite, path, direction, note}` where `path` is a
    "/"-separated key path into the suite JSON ("/" because bench keys
    themselves contain dots, e.g. density "0.01"). `direction: higher`
    means bigger is better; a row regresses when
    best/baseline < 1 - tolerance (reciprocal for `lower`).
  * **Explicit refresh.** `--update-baselines` rewrites the checked-in
    baseline files from the fresh run (tracked paths take the
    best-across-repeats value); commit the result. Perf *improvements*
    never fail the gate — they just make the next `--update-baselines`
    raise the bar.

Exit code: 0 clean, 1 regression (only with `--gate`), 2 usage/IO error.
"""

from __future__ import annotations

import argparse
import glob
import json
import os
import shutil
from typing import Any, Dict, List, Optional

DEFAULT_TOLERANCE = 0.20
BASELINES_DIR = os.path.join(os.path.dirname(__file__), "baselines")


def get_path(doc: Any, path: str):
    """Walk a "/"-separated key path through nested dicts."""
    node = doc
    for part in path.split("/"):
        if not isinstance(node, dict) or part not in node:
            return None
        node = node[part]
    return node


def set_path(doc: Dict, path: str, value) -> bool:
    parts = path.split("/")
    node = doc
    for part in parts[:-1]:
        if not isinstance(node, dict) or part not in node:
            return False
        node = node[part]
    if not isinstance(node, dict) or parts[-1] not in node:
        return False
    node[parts[-1]] = value
    return True


def repeat_dirs(fresh_dir: str) -> List[str]:
    """`--repeats K` layout (r0/..r{K-1}/) or a single flat run dir."""
    subs = sorted(d for d in glob.glob(os.path.join(fresh_dir, "r*"))
                  if os.path.isdir(d) and d.rsplit(os.sep, 1)[-1][1:].isdigit())
    return subs or [fresh_dir]


def load_suite(run_dir: str, suite: str) -> Optional[Dict]:
    """Load one BENCH_<suite>.json; missing OR corrupt files degrade to
    None (a warning + a "missing" row in the report) instead of killing
    the gate — a truncated artifact from a preempted nightly runner must
    not mask the rows that did land."""
    path = os.path.join(run_dir, f"BENCH_{suite}.json")
    if not os.path.exists(path):
        return None
    try:
        with open(path) as f:
            return json.load(f)
    except (OSError, ValueError) as e:
        print(f"warning: unreadable bench file {path}: {e}")
        return None


def compare(fresh_runs: Dict[str, List[Dict]], baselines: Dict[str, Dict],
            tracked: List[Dict], tolerance: float = DEFAULT_TOLERANCE
            ) -> Dict:
    """Pure core (unit-testable): diff loaded docs along the manifest.

    fresh_runs: suite -> list of loaded BENCH docs (one per repeat);
    baselines: suite -> loaded baseline BENCH doc; tracked: manifest rows.
    Returns {"rows": [...], "regressions": [...], "missing": [...]}.
    """
    rows, regressions, missing = [], [], []
    for spec in tracked:
        suite, path = spec["suite"], spec["path"]
        direction = spec.get("direction", "higher")
        tol = float(spec.get("tolerance", tolerance))
        base_doc = baselines.get(suite)
        base = get_path(base_doc, path) if base_doc else None
        fresh = [v for v in (get_path(doc, path)
                             for doc in fresh_runs.get(suite, []))
                 if isinstance(v, (int, float))]
        if not isinstance(base, (int, float)) or not fresh:
            missing.append({"suite": suite, "path": path,
                            "have_baseline": isinstance(base, (int, float)),
                            "n_fresh": len(fresh)})
            continue
        best = max(fresh) if direction == "higher" else min(fresh)
        med = sorted(fresh)[len(fresh) // 2]
        if direction == "higher":
            ratio, med_ratio = best / base, med / base
        else:
            ratio, med_ratio = base / best, base / med
        row = {"suite": suite, "path": path, "direction": direction,
               "baseline": base, "best": best, "median": med,
               "ratio": ratio, "median_ratio": med_ratio,
               "tolerance": tol, "n_repeats": len(fresh),
               "regressed": ratio < 1.0 - tol,
               "note": spec.get("note", "")}
        rows.append(row)
        if row["regressed"]:
            regressions.append(row)
    return {"rows": rows, "regressions": regressions, "missing": missing,
            "tolerance": tolerance}


def render_table(report: Dict) -> str:
    lines = ["| suite | metric | baseline | best | ratio | status |",
             "|---|---|---|---|---|---|"]
    for r in report["rows"]:
        status = "**REGRESSED**" if r["regressed"] else "ok"
        lines.append(
            f"| {r['suite']} | `{r['path']}` | {r['baseline']:.3f} | "
            f"{r['best']:.3f} | {r['ratio']:.2f} | {status} |")
    for m in report["missing"]:
        lines.append(f"| {m['suite']} | `{m['path']}` | — | — | — | "
                     f"missing |")
    return "\n".join(lines)


def update_baselines(fresh_dir: str, baselines_dir: str,
                     tracked: List[Dict]) -> List[str]:
    """Refresh baseline files: copy the first repeat, then overwrite every
    tracked path with its best-across-repeats value."""
    runs = repeat_dirs(fresh_dir)
    os.makedirs(baselines_dir, exist_ok=True)
    updated = []
    for suite in sorted({t["suite"] for t in tracked}):
        src = next((os.path.join(d, f"BENCH_{suite}.json") for d in runs
                    if os.path.exists(os.path.join(d,
                                                   f"BENCH_{suite}.json"))),
                   None)
        if src is None:
            continue
        dst = os.path.join(baselines_dir, f"BENCH_{suite}.json")
        shutil.copyfile(src, dst)
        with open(dst) as f:
            doc = json.load(f)
        docs = [d for d in (load_suite(r, suite) for r in runs) if d]
        for spec in (t for t in tracked if t["suite"] == suite):
            vals = [v for v in (get_path(d, spec["path"]) for d in docs)
                    if isinstance(v, (int, float))]
            if vals:
                best = (max(vals) if spec.get("direction", "higher") ==
                        "higher" else min(vals))
                set_path(doc, spec["path"], best)
        with open(dst, "w") as f:
            json.dump(doc, f, indent=1, default=str)
        updated.append(dst)
    return updated


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="Diff fresh BENCH_*.json against checked-in baselines.")
    ap.add_argument("fresh_dir",
                    help="fresh bench output dir (flat, or r*/ repeats)")
    ap.add_argument("--baselines", default=BASELINES_DIR)
    ap.add_argument("--tracked", default=None,
                    help="manifest path (default <baselines>/tracked.json)")
    ap.add_argument("--tolerance", type=float, default=DEFAULT_TOLERANCE)
    ap.add_argument("--gate", action="store_true",
                    help="exit 1 when any tracked row regresses")
    ap.add_argument("--update-baselines", action="store_true",
                    help="rewrite the baseline files from this run")
    ap.add_argument("--github-summary", action="store_true",
                    help="append the diff table to $GITHUB_STEP_SUMMARY")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write the full diff report as JSON")
    args = ap.parse_args(argv)

    tracked_path = args.tracked or os.path.join(args.baselines,
                                                "tracked.json")
    if not os.path.exists(tracked_path):
        print(f"no tracked manifest at {tracked_path}")
        return 2
    with open(tracked_path) as f:
        tracked = json.load(f)["tracked"]

    if args.update_baselines:
        updated = update_baselines(args.fresh_dir, args.baselines, tracked)
        for path in updated:
            print(f"baseline <- {path}")
        if not updated:
            print(f"no BENCH_*.json found under {args.fresh_dir}")
            return 2
        return 0

    runs = repeat_dirs(args.fresh_dir)
    fresh_runs = {s: [d for d in (load_suite(r, s) for r in runs) if d]
                  for s in {t["suite"] for t in tracked}}
    baselines = {s: load_suite(args.baselines, s)
                 for s in {t["suite"] for t in tracked}}
    report = compare(fresh_runs, {k: v for k, v in baselines.items() if v},
                     tracked, args.tolerance)

    table = render_table(report)
    print(f"perf gate: {len(runs)} repeat(s), "
          f"tolerance {args.tolerance:.0%}\n")
    print(table)
    n_reg = len(report["regressions"])
    verdict = (f"\n{n_reg} tracked row(s) regressed beyond "
               f"{args.tolerance:.0%}" if n_reg else
               "\nall tracked rows within tolerance")
    print(verdict)

    if args.json:
        with open(args.json, "w") as f:
            json.dump(report, f, indent=1, default=str)
        print(f"report -> {args.json}")
    if args.github_summary and os.environ.get("GITHUB_STEP_SUMMARY"):
        with open(os.environ["GITHUB_STEP_SUMMARY"], "a") as f:
            f.write(f"## Perf gate\n\n{table}\n{verdict}\n")
    if report["missing"]:
        stale = [m for m in report["missing"] if not m["have_baseline"]]
        print(f"warning: {len(report['missing'])} tracked row(s) missing "
              f"from this run (not gated)")
        if stale:
            print(f"  {len(stale)} of them have no checked-in baseline — "
                  f"run with --update-baselines after a healthy bench run "
                  f"and commit the result")
    return 1 if (args.gate and n_reg) else 0


if __name__ == "__main__":
    raise SystemExit(main())
