"""Fig. 13d reproduction: Table II SNNs on TaiBai (behavioural simulator)
vs a dense-GPU comparator — power ratio and efficiency ratio.

The paper's own numbers come from its chip simulator (§V-B: 'We use the chip
simulator to obtain the running power consumption and running time'); we run
the same protocol: measured per-layer spike rates drive the event cost
model, the GPU comparator burns dense FLOPs regardless of sparsity.

Paper claims: power reduced 65-338x, efficiency improved 6-20x, with
PLIF-Net (8% spike rate) ahead of the 13%-rate models.
"""

from __future__ import annotations

from typing import Dict, List

import numpy as np

from repro.configs.snn_models import MODELS, to_ops
from repro.core.mapping import compile_network
from repro.core.simulator import LayerStats, energy_per_sop, simulate

# measured-on-model spike rates from the paper §V-C1 (PLIF-Net 8%, the other
# two 13%); per-layer rates jitter around the model mean as real runs do.
MODEL_RATES = {"plif_net": 0.08, "5blocks_net": 0.13, "resnet19": 0.13}
TIMESTEPS = {"plif_net": 4, "5blocks_net": 8, "resnet19": 4}


def _layer_stats(model: str, rng) -> List[LayerStats]:
    specs, _ = MODELS[model]()
    ops = to_ops(specs)
    rate = MODEL_RATES[model]
    stats = []
    for op in ops:
        if op.n_neurons == 0:
            continue
        r = float(np.clip(rng.normal(rate, rate * 0.2), 0.01, 0.6))
        dense_flops = 2.0 * op.n_neurons * op.fan_in
        stats.append(LayerStats(op.name, op.n_neurons, op.fan_in, r,
                                dense_flops))
    return stats


def run() -> Dict:
    print("=== Fig. 13d: Table II SNNs, TaiBai (sim) vs GPU ===")
    rng = np.random.default_rng(0)
    out = {}
    for model in ("plif_net", "5blocks_net", "resnet19"):
        stats = _layer_stats(model, rng)
        n_cores = compile_network(to_ops(MODELS[model]()[0]),
                                  objective="cores",
                                  anneal_iters=50).meta["n_cores"]
        n_chips = max(1, -(-n_cores // 1056))
        rep = simulate(stats, timesteps=TIMESTEPS[model],
                       inter_chip_fraction=0.1 if n_chips > 1 else 0.0)
        # charge the static power of EVERY chip in the deployment (the
        # paper's dozens-of-chips models pay this; §V-C1's stated reason
        # the big models' efficiency drops)
        from repro.core.simulator import STATIC_W
        energy = rep.energy_j + (n_chips - 1) * STATIC_W * rep.time_s
        power = energy / rep.time_s
        eff = (rep.throughput_fps / power) / (rep.gpu_fps / rep.gpu_power_w)
        out[model] = {
            "n_cores": n_cores, "n_chips": n_chips,
            "taibai_power_w": power, "gpu_power_w": rep.gpu_power_w,
            "power_ratio_x": rep.gpu_power_w / power,
            "efficiency_x": eff,
            "energy_per_sop_pj": energy_per_sop(rep),
        }
        print(f"{model:12s} cores {n_cores:5d} (chips {n_chips:3d})  "
              f"power {power:6.2f} W vs GPU {rep.gpu_power_w:5.0f} W "
              f"({out[model]['power_ratio_x']:6.1f}x)   FPS/W ratio {eff:6.1f}x")
    ratios = [m["power_ratio_x"] for m in out.values()]
    effs = [m["efficiency_x"] for m in out.values()]
    print(f"power ratio range {min(ratios):.0f}-{max(ratios):.0f}x "
          f"(paper: 65-338x); efficiency {min(effs):.0f}-{max(effs):.0f}x "
          f"(paper: 6-20x)")

    # Tie the simulator numbers to a *measured* TPU-analogue data point:
    # the same spike-sparsity argument, run through the execution-plan
    # compiler on this host (full sweep: the snn_engine suite).
    import jax
    import jax.numpy as jnp

    from benchmarks.bench_snn_engine import measure
    from repro.core.snn_layers import make_dhsnn_shd

    nodes, params = make_dhsnn_shd(jax.random.PRNGKey(0), n_hidden=64,
                                   dendritic=False)
    x = (jax.random.uniform(jax.random.PRNGKey(1), (1000, 1, 700)) < 0.08
         ).astype(jnp.float32)
    eng = measure("shd_ff", nodes, params, x, repeats=7)
    out["engine"] = eng
    print(f"measured engine (stepper -> plan, SHD streaming): "
          f"{eng['stepper_ms']:.2f} -> {eng['plan_ms']:.2f} ms "
          f"({eng['speedup_x']:.2f}x)")
    return out


if __name__ == "__main__":
    run()
