"""Run every benchmark: `PYTHONPATH=src python -m benchmarks.run`.

Writes the aggregate to experiments/bench_results.json."""

from __future__ import annotations

import json
import os
import time
import traceback

from benchmarks import (bench_applications, bench_energy, bench_kernels,
                        bench_mapping_tradeoff, bench_roofline,
                        bench_snn_models, bench_spiking_lm,
                        bench_topology_storage)

SUITES = [
    ("topology_storage", bench_topology_storage),
    ("snn_models", bench_snn_models),
    ("mapping_tradeoff", bench_mapping_tradeoff),
    ("kernels", bench_kernels),
    ("energy", bench_energy),
    ("applications", bench_applications),
    ("spiking_lm", bench_spiking_lm),
    ("roofline", bench_roofline),
]


def main():
    results = {}
    failures = 0
    for name, mod in SUITES:
        print(f"\n{'='*72}\n[{name}]")
        t0 = time.time()
        try:
            results[name] = {"result": mod.run(),
                             "seconds": round(time.time() - t0, 1)}
        except Exception as e:
            failures += 1
            results[name] = {"error": repr(e)}
            traceback.print_exc()
    os.makedirs("experiments", exist_ok=True)
    with open("experiments/bench_results.json", "w") as f:
        json.dump(results, f, indent=1, default=str)
    print(f"\n{'='*72}\nwrote experiments/bench_results.json; "
          f"{len(SUITES) - failures}/{len(SUITES)} suites ok")
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
