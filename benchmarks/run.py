"""Run benchmark suites: `PYTHONPATH=src python -m benchmarks.run [--only a,b]`.

Emits machine-readable JSON so CI can archive a perf trajectory:

  experiments/BENCH_<suite>.json   one file per suite, schema below
  experiments/bench_results.json   the aggregate (back-compat)

`--repeats K` replays the selection into `r0/..r{K-1}/` subdirectories;
`benchmarks/compare.py` takes the per-row best across repeats before
gating, so one noisy repeat cannot fake a regression.

Per-suite schema (v1):
  {"schema": 1, "suite": str, "created_unix": float, "host": {...},
   "seconds": float, "ok": bool, "result": {...} | "error": str}

`--only kernels,topology_storage` is the CI benchmarks-smoke selection.
"""

from __future__ import annotations

import argparse
import json
import os
import platform
import subprocess
import time
import traceback

from benchmarks import (bench_applications, bench_energy, bench_kernels,
                        bench_mapping_tradeoff, bench_plasticity,
                        bench_roofline, bench_serving, bench_snn_engine,
                        bench_snn_models, bench_spiking_lm,
                        bench_topology_exec, bench_topology_storage)

SUITES = [
    ("topology_storage", bench_topology_storage),
    ("topology_exec", bench_topology_exec),
    ("snn_models", bench_snn_models),
    ("snn_engine", bench_snn_engine),
    ("serving", bench_serving),
    ("plasticity", bench_plasticity),
    ("mapping_tradeoff", bench_mapping_tradeoff),
    ("kernels", bench_kernels),
    ("energy", bench_energy),
    ("applications", bench_applications),
    ("spiking_lm", bench_spiking_lm),
    ("roofline", bench_roofline),
]

SCHEMA_VERSION = 1


def _git_sha() -> str:
    try:
        return subprocess.run(["git", "rev-parse", "--short", "HEAD"],
                              capture_output=True, text=True, timeout=5,
                              ).stdout.strip() or "unknown"
    except Exception:
        return "unknown"


def _host_meta() -> dict:
    import jax

    return {
        "backend": jax.default_backend(),
        "device_count": jax.device_count(),
        "jax_version": jax.__version__,
        "python": platform.python_version(),
        "platform": platform.platform(),
        "git_sha": _git_sha(),
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="Run benchmark suites and emit BENCH_*.json artifacts.")
    ap.add_argument("--only", default="",
                    help="comma-separated suite names (default: all)")
    ap.add_argument("--out-dir", default="experiments",
                    help="directory for BENCH_*.json + aggregate")
    ap.add_argument("--list", action="store_true",
                    help="list suite names and exit")
    ap.add_argument("--repeats", type=int, default=1, metavar="K",
                    help="run the selection K times into r0/..r{K-1}/ "
                         "subdirs (the perf gate's min-of-k noise guard)")
    args = ap.parse_args(argv)

    if args.list:
        for name, _ in SUITES:
            print(name)
        return 0

    selected = SUITES
    if args.only:
        wanted = [s.strip() for s in args.only.split(",") if s.strip()]
        known = {name for name, _ in SUITES}
        unknown = sorted(set(wanted) - known)
        if unknown:
            ap.error(f"unknown suites {unknown}; known: {sorted(known)}")
        selected = [(n, m) for n, m in SUITES if n in wanted]

    if args.repeats < 1:
        ap.error("--repeats must be >= 1")
    if args.repeats > 1:
        rc = 0
        for i in range(args.repeats):
            sub = os.path.join(args.out_dir, f"r{i}")
            print(f"\n########## repeat {i} -> {sub} ##########")
            rc |= _run_suites(selected, sub)
        return rc
    return _run_suites(selected, args.out_dir)


def _run_suites(selected, out_dir: str) -> int:
    host = _host_meta()
    os.makedirs(out_dir, exist_ok=True)
    aggregate = {"schema": SCHEMA_VERSION, "created_unix": time.time(),
                 "host": host, "suites": {}}
    failures = 0
    for name, mod in selected:
        print(f"\n{'='*72}\n[{name}]")
        entry = {"schema": SCHEMA_VERSION, "suite": name,
                 "created_unix": time.time(), "host": host}
        t0 = time.time()
        try:
            entry["result"] = mod.run()
            entry["ok"] = True
        except Exception as e:
            failures += 1
            entry["error"] = repr(e)
            entry["ok"] = False
            traceback.print_exc()
        entry["seconds"] = round(time.time() - t0, 1)
        path = os.path.join(out_dir, f"BENCH_{name}.json")
        with open(path, "w") as f:
            json.dump(entry, f, indent=1, default=str)
        print(f"[{name}] {'ok' if entry['ok'] else 'FAILED'} "
              f"in {entry['seconds']}s -> {path}")
        aggregate["suites"][name] = entry
    agg_path = os.path.join(out_dir, "bench_results.json")
    with open(agg_path, "w") as f:
        json.dump(aggregate, f, indent=1, default=str)
    print(f"\n{'='*72}\nwrote {agg_path}; "
          f"{len(selected) - failures}/{len(selected)} suites ok")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
