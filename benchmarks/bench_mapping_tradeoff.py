"""Fig. 13e reproduction: compiler-controlled mapping of one SNN, sweeping
the optimization objective from minimum-cores to maximum-throughput.

Paper: cores rise 4x (182 -> 749) while energy efficiency falls 1.7x
(6190 -> 3590 FPS/W) as the objective moves toward throughput."""

from __future__ import annotations

import dataclasses
from typing import Dict

import numpy as np

from repro import analysis
from repro.configs.snn_models import MODELS, to_ops
from repro.core.mapping import CORE_NEURONS, compile_network, fuse_ops, merge_cores, partition
from repro.core.simulator import LayerStats, simulate


def run() -> Dict:
    print("=== Fig. 13e: cores <-> throughput/efficiency trade-off ===")
    specs, _ = MODELS["5blocks_net"]()
    ops = to_ops(specs)
    rng = np.random.default_rng(1)
    points = []
    # sweep the per-core population budget: small budget = spread = throughput
    for frac in (1.0, 0.5, 0.25, 0.125):
        ir = fuse_ops([o for o in ops])
        budget = max(8, int(CORE_NEURONS * frac))
        cores = partition(ir, core_neurons=budget)
        if frac == 1.0:
            cores = merge_cores(cores, ir)
        # every swept placement must pass the static validator (TB4xx)
        bad = analysis.at_least(
            analysis.check_cores(cores, ir, core_neurons=budget), "error")
        assert not bad, "\n".join(str(d) for d in bad)
        n = len(cores)
        stats = [LayerStats(o.name, o.n_neurons, o.fan_in, 0.13,
                            2.0 * o.n_neurons * o.fan_in)
                 for o in ir if o.n_neurons]
        # more cores = more parallel compute lanes = faster, but every
        # spike multicasts to more regions over longer routes = more energy
        rep = simulate(stats, timesteps=8, parallel_send=4,
                       parallel_speedup=1.0 / frac,
                       replication=1.0 / frac,
                       hops_per_packet=2.0 + 2.0 / frac)
        eff = rep.throughput_fps / rep.power_w
        points.append({"core_budget_frac": frac, "n_cores": n,
                       "fps": rep.throughput_fps, "power_w": rep.power_w,
                       "fps_per_w": eff})
        print(f"budget {frac:5.3f}  cores {n:5d}  fps {rep.throughput_fps:9.1f}  "
              f"eff {eff:9.1f} FPS/W")
    # one end-to-end placement through the full validator (positions too)
    fresh = to_ops(MODELS["5blocks_net"]()[0])
    mapped = compile_network(fresh, anneal_iters=100)
    ir = fuse_ops([dataclasses.replace(o) for o in fresh])
    bad = analysis.at_least(analysis.check_mapping(mapped, ir), "error")
    assert not bad, "\n".join(str(d) for d in bad)
    c = [p["n_cores"] for p in points]
    e = [p["fps_per_w"] for p in points]
    print(f"cores x{max(c)/min(c):.1f} (paper: x4.1), "
          f"efficiency /{max(e)/min(e):.2f} (paper: /1.7)")
    return {"points": points, "cores_ratio": max(c) / min(c),
            "efficiency_drop": max(e) / min(e)}


if __name__ == "__main__":
    run()
