"""Table III/IV reproduction: chip characteristics + energy per SOP.

Runs the behavioural simulator at the chip's own operating point (Table III:
1.83 W typical at 528 GSOPS peak) and reports the achieved pJ/SOP against
the paper's 2.61 pJ and the Table IV competitor list (static data)."""

from __future__ import annotations

from typing import Dict

from repro.core.simulator import (CHIP_POWER_W, CLOCK_HZ, E_SOP_PJ,
                                  PEAK_GSOPS, LayerStats, energy_per_sop,
                                  simulate)

TABLE_IV = {       # chip -> (pJ/SOP, programmability)
    "TrueNorth": (26.0, "LIF only"),
    "Loihi": (23.6, "LIF only"),
    "Tianjic": (1.54, "LIF only"),
    "PAICORE": (0.19, "LIF only (1-bit)"),
    "SpiNNaker": (11000.0, "fully programmable"),
    "Loihi2": (7.8, "programmable"),
    "Darwin3": (5.47, "programmable"),
    "TaiBai (paper)": (2.61, "fully programmable"),
}


def run() -> Dict:
    print("=== Table III/IV: chip characteristics + energy/SOP ===")
    # a workload dense enough to keep every NC busy: 264K neurons at the
    # chip's peak synaptic rate
    layers = [LayerStats("full", 264_000, 1000, 0.25,
                     2.0 * 264_000 * 1000)]
    rep = simulate(layers, timesteps=1000)
    achieved = energy_per_sop(rep)
    print(f"simulated chip power {rep.power_w:.2f} W "
          f"(Table III: {CHIP_POWER_W} W typical)")
    print(f"achieved energy/SOP {achieved:.2f} pJ "
          f"(Table IV: {E_SOP_PJ} pJ; dynamic-only constant)")
    print(f"peak {PEAK_GSOPS/1e9:.0f} GSOPS @ {CLOCK_HZ/1e6:.0f} MHz")
    print("--- Table IV comparison (published numbers) ---")
    for chip, (pj, prog) in TABLE_IV.items():
        print(f"  {chip:16s} {pj:10.2f} pJ/SOP   {prog}")
    return {"power_w": rep.power_w, "pj_per_sop": achieved,
            "table_iv": TABLE_IV}


if __name__ == "__main__":
    run()
